//! Property-based invariants (via the in-tree `testing::prop` harness):
//! the paper's Assumption 1 bound, wire-format exactness, error-feedback
//! conservation, aggregation linearity, optimizer-state monotonicity, and
//! shard-slicing/sharded-server exactness over randomized shapes and
//! gradient distributions.

use comp_ams::algo::average_payloads;
use comp_ams::compress::{
    as_views, BlockSign, Compressor, ErrorFeedback, Identity, Payload, RandomK, TopK,
};
use comp_ams::optim::{AmsGrad, ServerOpt};
use comp_ams::testing::prop::{check, Gen};
use comp_ams::util::math;

fn random_compressor(g: &mut Gen) -> Box<dyn Compressor> {
    match g.rng.gen_range(4) {
        0 => Box::new(TopK::new(g.f32_range(0.005, 1.0))),
        1 => Box::new(BlockSign::new(g.size(1, 512))),
        2 => Box::new(RandomK::new(g.f32_range(0.005, 1.0), g.rng.next_u64())),
        _ => Box::new(Identity),
    }
}

#[test]
fn prop_q_deviate_bound_deterministic_compressors() {
    // Assumption 1: ||C(x) - x|| <= q ||x|| for Top-k and Block-Sign
    // (deterministic q-deviate compressors; Remark 1 gives their q).
    check("q_deviate", 150, |g| {
        let d = g.size(1, 5000);
        let x = g.grad_vec(d);
        let mut cs: Vec<Box<dyn Compressor>> = vec![
            Box::new(TopK::new(g.f32_range(0.005, 1.0))),
            Box::new(BlockSign::new(g.size(1, 512))),
        ];
        for c in &mut cs {
            let p = c.compress(&x);
            let dense = p.to_dense(d).unwrap();
            let err: f64 = x
                .iter()
                .zip(&dense)
                .map(|(&a, &b)| ((a - b) as f64).powi(2))
                .sum();
            let q2 = (c.q(d) as f64).powi(2);
            let bound = q2 * math::norm2_sq(&x) + 1e-5;
            assert!(err <= bound, "{}: d={d} err={err} bound={bound}", c.name());
        }
    });
}

#[test]
fn prop_wire_roundtrip_exact() {
    // encode/decode must be the identity, and the ledger must equal the
    // encoded length exactly, for every payload any compressor can emit.
    check("wire_roundtrip", 200, |g| {
        let d = g.size(1, 3000);
        let x = g.grad_vec(d);
        let mut c = random_compressor(g);
        let p = c.compress(&x);
        let bytes = p.encode();
        assert_eq!(bytes.len() as u64 * 8, p.wire_bits());
        let q = Payload::decode(&bytes).unwrap();
        assert_eq!(p, q);
        // Dense reconstruction must also survive the byte round-trip.
        assert_eq!(p.to_dense(d).unwrap(), q.to_dense(d).unwrap());
    });
}

#[test]
fn prop_error_feedback_conservation() {
    // decode(C(g+e)) + e' == g + e (Alg. 2 lines 7-8) to f32 rounding.
    check("ef_conservation", 100, |g| {
        let d = g.size(1, 2000);
        let mut ef = ErrorFeedback::new(d, true);
        let mut c = random_compressor(g);
        for round in 0..5 {
            let grad = g.grad_vec(d);
            let corrected: Vec<f32> = grad
                .iter()
                .zip(ef.residual())
                .map(|(&a, &b)| a + b)
                .collect();
            let p = ef.compress(&grad, c.as_mut()).unwrap();
            let sent = p.to_dense(d).unwrap();
            for i in 0..d {
                let lhs = sent[i] + ef.residual()[i];
                assert!(
                    (lhs - corrected[i]).abs() <= 1e-4 * corrected[i].abs().max(1.0),
                    "round {round} coord {i}: {lhs} vs {}",
                    corrected[i]
                );
            }
        }
    });
}

#[test]
fn prop_average_payloads_matches_dense_mean() {
    check("avg_linearity", 100, |g| {
        let d = g.size(1, 1500);
        let n = g.size(1, 8);
        let mut msgs = Vec::new();
        let mut dense = Vec::new();
        for _ in 0..n {
            let x = g.grad_vec(d);
            let mut c = random_compressor(g);
            let p = c.compress(&x);
            dense.push(p.to_dense(d).unwrap());
            msgs.push(p);
        }
        let mut avg = Vec::new();
        average_payloads(&as_views(&msgs), d, &mut avg).unwrap();
        for i in 0..d {
            let want: f32 = dense.iter().map(|v| v[i]).sum::<f32>() / n as f32;
            assert!((avg[i] - want).abs() <= 1e-4 * want.abs().max(1.0));
        }
    });
}

#[test]
fn prop_amsgrad_vhat_monotone_and_step_bounded() {
    check("amsgrad_invariants", 60, |g| {
        let d = g.size(1, 300);
        let mut opt = AmsGrad::default_hp(d);
        let mut theta = g.grad_vec(d);
        let lr = g.f32_range(1e-4, 0.1);
        let mut prev_vhat = vec![0.0f32; d];
        for _ in 0..10 {
            let grad = g.grad_vec(d);
            let before = theta.clone();
            opt.step(&mut theta, &grad, lr);
            for i in 0..d {
                assert!(opt.vhat[i] >= prev_vhat[i], "vhat decreased");
                // |Δθ_i| <= lr * |m_i| / sqrt(vhat_i) <= lr / sqrt(1-β2)
                // whenever vhat >= (1-β2) m² — always true since vhat >= v
                // >= (1-β2) g² and |m| <= max|g| seen. Use the loose bound.
                let step = (theta[i] - before[i]).abs();
                assert!(step <= lr * 40.0, "step {step} too large for lr {lr}");
            }
            prev_vhat = opt.vhat.clone();
        }
    });
}

#[test]
fn prop_topk_selection_matches_sorted_reference() {
    // The partial select (`select_nth_unstable_by`) must pick exactly the
    // set a full sort by (|x| desc, index asc) would — including under
    // heavy magnitude ties, where a non-total comparator would let the
    // pivot choice decide which tied coordinate survives.
    check("topk_selection", 150, |g| {
        let d = g.size(1, 3000);
        // Quantized magnitudes force duplicate |x| values.
        let x: Vec<f32> =
            (0..d).map(|_| (g.rng.normal() * 4.0).round() / 4.0).collect();
        let ratio = g.f32_range(0.005, 1.0);
        let mut c = TopK::new(ratio);
        let k = c.k_for(d);
        let (idx, val) = match c.compress(&x) {
            Payload::Sparse { idx, val, .. } => (idx, val),
            other => panic!("topk emitted {other:?}"),
        };
        let mut order: Vec<u32> = (0..d as u32).collect();
        order.sort_by(|&a, &b| {
            x[b as usize]
                .abs()
                .total_cmp(&x[a as usize].abs())
                .then(a.cmp(&b))
        });
        let mut want = order[..k].to_vec();
        want.sort_unstable();
        assert_eq!(idx, want, "d={d} ratio={ratio}");
        for (i, &ix) in idx.iter().enumerate() {
            assert_eq!(
                val[i].to_bits(),
                x[ix as usize].to_bits(),
                "value at selected index {ix}"
            );
        }
    });
}

#[test]
fn prop_topk_payload_is_best_k_approximation() {
    // Top-k minimizes ||C(x) - x|| over all k-sparse selections: its error
    // must be <= Random-k's error on the same vector and same k.
    check("topk_optimality", 80, |g| {
        let d = g.size(2, 2000);
        let ratio = g.f32_range(0.01, 0.9);
        let x = g.grad_vec(d);
        let mut topk = TopK::new(ratio);
        let mut randk = RandomK::new(ratio, g.rng.next_u64());
        let et: f64 = {
            let dn = topk.compress(&x).to_dense(d).unwrap();
            x.iter().zip(&dn).map(|(&a, &b)| ((a - b) as f64).powi(2)).sum()
        };
        let er: f64 = {
            let dn = randk.compress(&x).to_dense(d).unwrap();
            x.iter().zip(&dn).map(|(&a, &b)| ((a - b) as f64).powi(2)).sum()
        };
        assert!(et <= er + 1e-6, "topk err {et} > randomk err {er}");
    });
}

#[test]
fn prop_config_json_roundtrip() {
    use comp_ams::config::{LrSchedule, TrainConfig};
    check("config_roundtrip", 60, |g| {
        let models = ["quadratic", "logistic", "mnist_cnn", "imdb_lstm"];
        let algos = ["dist-ams", "comp-ams-topk:0.01", "qadam", "1bitadam:7", "dist-sgd"];
        let mut cfg = TrainConfig::preset(
            models[g.rng.gen_range(models.len())],
            algos[g.rng.gen_range(algos.len())],
        );
        cfg.workers = g.size(1, 64);
        cfg.rounds = g.size(1, 100_000) as u64;
        cfg.lr = g.f32_range(1e-5, 1.0);
        cfg.seed = g.rng.next_u64() >> 12;
        if g.rng.next_f32() < 0.5 {
            cfg.schedule = LrSchedule::StepDecay {
                at: vec![g.size(1, 500) as u64, g.size(500, 1000) as u64],
                factor: g.f32_range(2.0, 10.0),
            };
        }
        let text = cfg.to_json().to_string_pretty();
        let back =
            TrainConfig::from_json(&comp_ams::util::json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.model, cfg.model);
        assert_eq!(back.algo, cfg.algo);
        assert_eq!(back.workers, cfg.workers);
        assert_eq!(back.rounds, cfg.rounds);
        assert_eq!(back.seed, cfg.seed);
        assert_eq!(back.schedule, cfg.schedule);
        assert!((back.lr - cfg.lr).abs() <= 1e-9 * cfg.lr.abs());
    });
}

#[test]
fn prop_worker_halves_are_send_and_threaded_is_bitwise_identical() {
    // The split-API contract: every WorkerAlgo is Send (compile-time), and
    // running the full worker pipeline (grad + EF + compress + encode) on
    // worker threads yields bitwise-identical losses AND uplink bits to
    // the sequential backend, for every protocol family.
    use comp_ams::algo::WorkerAlgo;
    use comp_ams::config::TrainConfig;
    use comp_ams::coordinator::trainer::train;

    fn assert_send<T: Send + ?Sized>() {}
    assert_send::<dyn WorkerAlgo>();
    assert_send::<Box<dyn WorkerAlgo>>();

    for algo in [
        "dist-ams",
        "comp-ams-topk:0.05",
        "comp-ams-blocksign:64",
        "qadam",
        "1bitadam:10",
        "dist-sgd",
    ] {
        let mut cfg = TrainConfig::preset("quadratic", algo);
        cfg.workers = 3;
        cfg.rounds = 30;
        cfg.lr = 0.01;
        cfg.eval_every = 0;
        let seq = train(&cfg).unwrap();
        cfg.threaded = true;
        let thr = train(&cfg).unwrap();
        assert_eq!(seq.metrics.len(), thr.metrics.len(), "{algo}");
        for (ma, mb) in seq.metrics.iter().zip(&thr.metrics) {
            assert_eq!(
                ma.train_loss.to_bits(),
                mb.train_loss.to_bits(),
                "{algo}: loss diverged at round {}",
                ma.round
            );
            assert_eq!(
                ma.uplink_bits, mb.uplink_bits,
                "{algo}: uplink diverged at round {}",
                ma.round
            );
        }
        assert_eq!(
            seq.uplink_bits_by_worker, thr.uplink_bits_by_worker,
            "{algo}: per-worker uplink breakdown diverged"
        );
    }
}

#[test]
fn prop_payload_slice_concat_reproduces_full_decode() {
    // Sharded-server routing invariant: splitting any payload kind by a
    // random (generally uneven, d % S != 0) contiguous partition and
    // re-concatenating the slice decodes reproduces the full decode
    // bitwise — so per-shard servers see exactly the coordinates the
    // full-θ server would.
    use comp_ams::compress::wire::f32_to_f16;
    check("payload_slice_concat", 150, |g| {
        let d = g.size(1, 2000);
        let x = g.grad_vec(d);
        // Every payload kind, not just what random_compressor emits:
        // dense, top-k sparse, random-k, block-sign, plus hand-built
        // layered-sign / quantized / f16-sparse messages.
        let mut payloads: Vec<Payload> = Vec::new();
        for c in &mut [
            Box::new(Identity) as Box<dyn Compressor>,
            Box::new(TopK::new(g.f32_range(0.005, 1.0))),
            Box::new(TopK::new_fp16(g.f32_range(0.005, 1.0))),
            Box::new(BlockSign::new(g.size(1, 512))),
            Box::new(RandomK::new(g.f32_range(0.005, 1.0), g.rng.next_u64())),
        ] {
            payloads.push(c.compress(&x));
        }
        let mut layer_sizes: Vec<u32> = Vec::new();
        let mut rest = d;
        while rest > 0 {
            let s = g.size(1, rest);
            layer_sizes.push(s as u32);
            rest -= s;
        }
        payloads.push(Payload::LayeredSigns {
            dim: d as u32,
            sizes: layer_sizes.clone(),
            scales: layer_sizes.iter().map(|_| g.f32_range(0.0, 3.0)).collect(),
            bits: comp_ams::compress::wire::pack_signs(&x),
        });
        payloads.push(Payload::Quantized {
            dim: d as u32,
            norm: g.f32_range(0.1, 10.0),
            levels: g.size(1, 127) as u8,
            q: x.iter().map(|&v| (v.clamp(-1.0, 1.0) * 4.0) as i8).collect(),
        });
        payloads.push(Payload::SparseF16 {
            dim: d as u32,
            idx: (0..d).step_by(3).map(|i| i as u32).collect(),
            val: (0..d).step_by(3).map(|i| f32_to_f16(x[i])).collect(),
        });
        let shards = g.size(1, d.min(8));
        // Uneven fenceposts: random interior cut points, sorted.
        let mut bounds: Vec<usize> = (0..shards - 1).map(|_| g.size(1, d)).collect();
        bounds.push(0);
        bounds.push(d);
        bounds.sort_unstable();
        bounds.dedup();

        for p in &payloads {
            let full = p.to_dense(d).unwrap();
            // The one-pass split (the sharded server's routing path) must
            // agree payload-for-payload with per-shard slice_range.
            let split = p.slice_into_shards(&bounds).unwrap();
            let mut rebuilt: Vec<f32> = Vec::with_capacity(d);
            for (k, w) in bounds.windows(2).enumerate() {
                let s = p.slice_range(w[0], w[1]).unwrap();
                assert_eq!(split[k], s, "slice_into_shards shard {k} of {p:?}");
                // Slices must survive the byte codec like any payload.
                let rt = Payload::decode(&s.encode()).unwrap();
                assert_eq!(rt, s);
                rebuilt.extend(s.to_dense(w[1] - w[0]).unwrap());
            }
            assert_eq!(rebuilt.len(), d);
            for i in 0..d {
                assert_eq!(
                    rebuilt[i].to_bits(),
                    full[i].to_bits(),
                    "kind {p:?} coord {i} of d={d} bounds={bounds:?}"
                );
            }
        }
    });
}

#[test]
fn prop_sharded_server_trajectory_bitwise_identical() {
    // The tentpole acceptance bar: for every protocol string, S=1 vs S=4
    // — on both the sequential and the threaded shard backend — produce
    // bitwise-identical loss trajectories AND final θ through the full
    // Trainer. Quadratic dim is 256, so also exercise S=3 (256 % 3 != 0).
    use comp_ams::config::TrainConfig;
    use comp_ams::coordinator::trainer::Trainer;

    fn run(cfg: &TrainConfig) -> (Vec<f32>, Vec<f32>) {
        let mut t = Trainer::new(cfg).unwrap();
        let mut losses = Vec::new();
        for r in 0..cfg.rounds {
            losses.push(t.step(r).unwrap());
        }
        (losses, t.theta)
    }

    for algo in [
        "dist-ams",
        "comp-ams-topk:0.05",
        "comp-ams-blocksign:64",
        "comp-ams-randomk:0.1",
        "qadam",
        "1bitadam:10",
        "dist-sgd",
    ] {
        let mut cfg = TrainConfig::preset("quadratic", algo);
        cfg.workers = 3;
        cfg.rounds = 30;
        cfg.lr = 0.01;
        cfg.eval_every = 0;
        let (base_loss, base_theta) = run(&cfg);
        for (shards, threaded) in [(4, false), (4, true), (3, true)] {
            cfg.server_shards = shards;
            cfg.server_threaded = threaded;
            let (loss, theta) = run(&cfg);
            for (r, (a, b)) in base_loss.iter().zip(&loss).enumerate() {
                assert_eq!(
                    a.to_bits(),
                    b.to_bits(),
                    "{algo} S={shards} threaded={threaded}: loss diverged at round {r}"
                );
            }
            for (i, (a, b)) in base_theta.iter().zip(&theta).enumerate() {
                assert_eq!(
                    a.to_bits(),
                    b.to_bits(),
                    "{algo} S={shards} threaded={threaded}: θ[{i}] diverged"
                );
            }
        }
    }
}

#[test]
fn prop_envelope_frames_every_payload_kind_exactly() {
    // Transport-framing invariant: wrapping any payload any compressor
    // can emit (plus hand-built layered/quantized/f16 messages) in an
    // Envelope and round-tripping the bytes is the identity — bitwise,
    // loss included — and the frame bill is exactly the 16-byte header
    // plus the payload's own wire bits.
    use comp_ams::compress::wire::f32_to_f16;
    use comp_ams::coordinator::transport::{Envelope, ENVELOPE_HEADER_BYTES};
    check("envelope_roundtrip", 150, |g| {
        let d = g.size(1, 2000);
        let x = g.grad_vec(d);
        let mut payloads: Vec<Payload> = Vec::new();
        for c in &mut [
            Box::new(Identity) as Box<dyn Compressor>,
            Box::new(TopK::new(g.f32_range(0.005, 1.0))),
            Box::new(TopK::new_fp16(g.f32_range(0.005, 1.0))),
            Box::new(BlockSign::new(g.size(1, 512))),
            Box::new(RandomK::new(g.f32_range(0.005, 1.0), g.rng.next_u64())),
        ] {
            payloads.push(c.compress(&x));
        }
        payloads.push(Payload::LayeredSigns {
            dim: d as u32,
            sizes: vec![d as u32],
            scales: vec![g.f32_range(0.0, 3.0)],
            bits: comp_ams::compress::wire::pack_signs(&x),
        });
        payloads.push(Payload::Quantized {
            dim: d as u32,
            norm: g.f32_range(0.1, 10.0),
            levels: g.size(1, 127) as u8,
            q: x.iter().map(|&v| (v.clamp(-1.0, 1.0) * 4.0) as i8).collect(),
        });
        payloads.push(Payload::SparseF16 {
            dim: d as u32,
            idx: (0..d).step_by(2).map(|i| i as u32).collect(),
            val: (0..d).step_by(2).map(|i| f32_to_f16(x[i])).collect(),
        });
        for p in payloads {
            let env = Envelope {
                wid: g.size(0, 65_000) as u32,
                round: g.rng.next_u64() >> 16,
                loss: g.rng.normal(),
                payload: p,
            };
            let bytes = env.encode();
            assert_eq!(bytes.len() as u64 * 8, env.wire_bits());
            assert_eq!(
                env.wire_bits(),
                ENVELOPE_HEADER_BYTES as u64 * 8 + env.payload.wire_bits(),
                "frame bill must be header + payload exactly"
            );
            let back = Envelope::decode(&bytes).unwrap();
            assert_eq!(back, env);
            assert_eq!(back.loss.to_bits(), env.loss.to_bits());
        }
    });
}

#[test]
fn prop_full_quorum_is_invariant_across_transports_and_backends() {
    // The tentpole acceptance bar: under the default full quorum (K = n),
    // the event-driven runtime reproduces the synchronous trajectory
    // bitwise — losses, uplink bits, final θ — for every protocol string,
    // across sequential vs threaded workers, InProc vs Loopback
    // transports, and quorum spelled 0 (default) or n explicitly.
    use comp_ams::config::TrainConfig;
    use comp_ams::coordinator::trainer::Trainer;

    fn run(cfg: &TrainConfig) -> (Vec<f32>, Vec<u64>, Vec<f32>, u64, u64) {
        let mut t = Trainer::new(cfg).unwrap();
        let mut losses = Vec::new();
        for r in 0..cfg.rounds {
            losses.push(t.step(r).unwrap());
        }
        let bits = t.ledger().uplink_bits_by_worker.clone();
        let stale = t.ledger().stale_uplinks;
        let dropped = t.ledger().dropped_uplinks;
        let theta = t.theta;
        (losses, bits, theta, stale, dropped)
    }

    // The six protocol strings of the acceptance bar, plus the
    // compressors whose payload kinds (quantized, random-k sparse, f16
    // sparse) the six don't emit — so every Payload kind crosses the
    // Loopback byte framing inside a real training loop.
    for algo in [
        "dist-ams",
        "comp-ams-topk:0.05",
        "comp-ams-blocksign:64",
        "qadam",
        "1bitadam:10",
        "dist-sgd",
        "comp-ams-qsgd:4",
        "comp-ams-randomk:0.1",
        "comp-ams-topk16:0.05",
    ] {
        let mut cfg = TrainConfig::preset("quadratic", algo);
        cfg.workers = 3;
        cfg.rounds = 30;
        cfg.lr = 0.01;
        cfg.eval_every = 0;
        let (base_loss, base_bits, base_theta, s0, d0) = run(&cfg);
        assert_eq!((s0, d0), (0, 0), "{algo}: staleness under full quorum");
        for (threaded, transport, quorum) in [
            (false, "loopback", 0),
            (true, "inproc", 0),
            (true, "loopback", 0),
            (false, "inproc", 3),
            (true, "loopback", 3),
        ] {
            cfg.threaded = threaded;
            cfg.transport = transport.into();
            cfg.quorum = quorum;
            let (loss, bits, theta, stale, dropped) = run(&cfg);
            let label =
                format!("{algo} threaded={threaded} transport={transport} K={quorum}");
            assert_eq!((stale, dropped), (0, 0), "{label}");
            assert_eq!(base_bits, bits, "{label}: per-worker uplink bits");
            for (r, (a, b)) in base_loss.iter().zip(&loss).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "{label}: loss at round {r}");
            }
            for (i, (a, b)) in base_theta.iter().zip(&theta).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "{label}: θ[{i}]");
            }
        }
    }
}

#[test]
fn prop_degenerate_tree_is_bitwise_identical_to_flat_star() {
    // The tree-topology acceptance bar: a degenerate tree — degree >= n
    // (one group spanning every worker), identity group compressor, no
    // downlink compression — reproduces the flat star bitwise in loss
    // and θ for every protocol string, across inproc/loopback. The
    // single sub-leader aggregates the same payloads in the same wid
    // order with the same estimator, forwards the exact dense mean, and
    // the root's mean over one message is the identity.
    //
    // Deliberately NOT compared: transmitted bits. The forwarded
    // sub-leader → root hop is a real extra message, so the tree run
    // legitimately bills more — the per-level ledger invariants for
    // that live in tests/tree.rs.
    use comp_ams::config::TrainConfig;
    use comp_ams::coordinator::trainer::Trainer;

    fn run(cfg: &TrainConfig) -> (Vec<f32>, Vec<f32>) {
        let mut t = Trainer::new(cfg).unwrap();
        let mut losses = Vec::new();
        for r in 0..cfg.rounds {
            losses.push(t.step(r).unwrap());
        }
        (losses, t.theta)
    }

    for algo in [
        "dist-ams",
        "comp-ams-topk:0.05",
        "comp-ams-blocksign:64",
        "qadam",
        "1bitadam:10",
        "dist-sgd",
    ] {
        for transport in ["inproc", "loopback"] {
            let mut cfg = TrainConfig::preset("quadratic", algo);
            cfg.workers = 3;
            cfg.rounds = 30;
            cfg.lr = 0.01;
            cfg.eval_every = 0;
            cfg.transport = transport.into();
            let (flat_loss, flat_theta) = run(&cfg);
            // degree 8 >= 3 workers: one group holds the whole fleet.
            cfg.topology = "tree:8".into();
            let (tree_loss, tree_theta) = run(&cfg);
            let label = format!("{algo} transport={transport}");
            assert_eq!(flat_loss.len(), tree_loss.len(), "{label}");
            for (r, (a, b)) in flat_loss.iter().zip(&tree_loss).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "{label}: loss at round {r}");
            }
            for (i, (a, b)) in flat_theta.iter().zip(&tree_theta).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "{label}: θ[{i}]");
            }
        }
    }
}

#[test]
fn prop_rng_streams_do_not_collide() {
    check("rng_streams", 40, |g| {
        let mut root = comp_ams::util::rng::Rng::seed(g.rng.next_u64());
        let n = g.size(2, 32);
        let mut streams: Vec<_> = (0..n).map(|i| root.split(i as u64)).collect();
        let firsts: Vec<u64> = streams.iter_mut().map(|s| s.next_u64()).collect();
        let set: std::collections::BTreeSet<_> = firsts.iter().collect();
        assert_eq!(set.len(), n, "stream collision");
    });
}
