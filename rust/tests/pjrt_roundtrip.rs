//! PJRT round-trip tests: the Rust↔artifact contract. These need
//! `make artifacts`; they self-skip (with a loud message) if the
//! artifacts directory is absent so `cargo test` works pre-build.

use std::path::{Path, PathBuf};
use std::rc::Rc;

use comp_ams::config::TrainConfig;
use comp_ams::coordinator::trainer::train;
use comp_ams::data::{vectors::GaussianVectors, Batch, BatchData};
use comp_ams::optim::{AmsGrad, ServerOpt};
use comp_ams::runtime::{ModelBundle, Runtime};
use comp_ams::util::rng::Rng;

fn artifacts() -> Option<PathBuf> {
    let p = PathBuf::from("artifacts");
    if p.join("manifest.json").exists() {
        Some(p)
    } else {
        eprintln!("SKIP: artifacts/ missing — run `make artifacts`");
        None
    }
}

fn load(name: &str) -> Option<(Rc<Runtime>, ModelBundle)> {
    let dir = artifacts()?;
    let rt = Rc::new(Runtime::cpu().expect("pjrt cpu"));
    let bundle = ModelBundle::load(&rt, Path::new(&dir), name).expect("load bundle");
    Some((rt, bundle))
}

fn logreg_batch(seed: u64) -> Batch {
    let ds = GaussianVectors::new(7, 64, 4, 0.5);
    let mut rng = Rng::seed(seed);
    comp_ams::data::make_batch(&ds, &mut rng, 16, None)
}

#[test]
fn grad_exe_matches_finite_differences() {
    let Some((_rt, bundle)) = load("logreg") else { return };
    let theta = bundle.init_theta.clone();
    let batch = logreg_batch(1);
    let (_, grad) = bundle.grad.run(&theta, &batch, 0).unwrap();
    assert_eq!(grad.len(), theta.len());
    // Central differences on a few coordinates through the *loss* output.
    let eps = 1e-2f32;
    for &i in &[0usize, 63, 130, 259] {
        let mut tp = theta.clone();
        tp[i] += eps;
        let (lp, _) = bundle.grad.run(&tp, &batch, 0).unwrap();
        let mut tm = theta.clone();
        tm[i] -= eps;
        let (lm, _) = bundle.grad.run(&tm, &batch, 0).unwrap();
        let fd = (lp - lm) / (2.0 * eps);
        assert!(
            (fd - grad[i]).abs() < 5e-2 * grad[i].abs().max(0.05),
            "coord {i}: fd={fd} grad={}",
            grad[i]
        );
    }
}

#[test]
fn grad_exe_is_deterministic_given_seed() {
    let Some((_rt, bundle)) = load("logreg") else { return };
    let theta = bundle.init_theta.clone();
    let batch = logreg_batch(2);
    let (l1, g1) = bundle.grad.run(&theta, &batch, 5).unwrap();
    let (l2, g2) = bundle.grad.run(&theta, &batch, 5).unwrap();
    assert_eq!(l1.to_bits(), l2.to_bits());
    assert_eq!(g1, g2);
}

#[test]
fn eval_exe_counts_are_bounded_and_loss_finite() {
    let Some((_rt, bundle)) = load("logreg") else { return };
    let batch = logreg_batch(3);
    let (loss, correct) = bundle.eval.run(&bundle.init_theta, &batch).unwrap();
    assert!(loss.is_finite());
    assert!(correct <= 16);
}

#[test]
fn pallas_fused_amsgrad_matches_pure_rust() {
    // The L1 kernel and the L3 reference implementation must agree to
    // f32 tolerance for several consecutive steps.
    let Some((_rt, bundle)) = load("logreg") else { return };
    let p = bundle.entry.p;
    let mut rng = Rng::seed(11);
    let mut rust_opt = AmsGrad::default_hp(p);
    let mut theta_rust = rng.normal_vec(p);
    let mut theta_pjrt = theta_rust.clone();
    let (mut m, mut v, mut vhat) = (vec![0.0f32; p], vec![0.0f32; p], vec![0.0f32; p]);
    for step in 0..5 {
        let g = rng.normal_vec(p);
        rust_opt.step(&mut theta_rust, &g, 1e-3);
        let (t2, m2, v2, vh2) = bundle
            .amsgrad
            .run(&theta_pjrt, &m, &v, &vhat, &g, 1e-3)
            .unwrap();
        theta_pjrt = t2;
        m = m2;
        v = v2;
        vhat = vh2;
        for i in 0..p {
            assert!(
                (theta_rust[i] - theta_pjrt[i]).abs() < 1e-5,
                "step {step} coord {i}: rust {} pjrt {}",
                theta_rust[i],
                theta_pjrt[i]
            );
            assert!((rust_opt.m[i] - m[i]).abs() < 1e-6);
            assert!((rust_opt.vhat[i] - vhat[i]).abs() < 1e-6);
        }
    }
}

#[test]
fn training_decreases_loss_on_pjrt_smoke_model() {
    if artifacts().is_none() {
        return;
    }
    let mut cfg = TrainConfig::preset("logreg", "comp-ams-topk:0.1");
    cfg.workers = 4;
    cfg.rounds = 40;
    cfg.lr = 0.01;
    cfg.eval_every = 0;
    let run = train(&cfg).unwrap();
    let first = run.metrics[0].train_loss;
    let last = run.final_train_loss(5);
    assert!(last < first * 0.8, "pjrt training stalled: {first} -> {last}");
    assert!(run.final_eval.accuracy > 0.4);
}

#[test]
fn fused_and_rust_server_updates_train_identically_enough() {
    if artifacts().is_none() {
        return;
    }
    let mut cfg = TrainConfig::preset("logreg", "dist-ams");
    cfg.workers = 2;
    cfg.rounds = 15;
    cfg.eval_every = 0;
    let rust_run = train(&cfg).unwrap();
    cfg.fused_update = true;
    let fused_run = train(&cfg).unwrap();
    for (a, b) in rust_run.metrics.iter().zip(&fused_run.metrics) {
        assert!(
            (a.train_loss - b.train_loss).abs() < 1e-4,
            "round {}: {} vs {}",
            a.round,
            a.train_loss,
            b.train_loss
        );
    }
}

#[test]
fn manifest_lists_all_default_models() {
    let Some(dir) = artifacts() else { return };
    let m = comp_ams::runtime::Manifest::load(&dir.join("manifest.json")).unwrap();
    for name in ["logreg", "mnist_cnn", "cifar_lenet", "cifar_resnet", "imdb_lstm", "lm_small"]
    {
        let e = m.model(name).unwrap();
        assert!(e.p > 0);
        assert!(dir.join(&e.files.grad).exists());
        assert!(dir.join(&e.files.init).exists());
    }
}

#[test]
fn batch_dtype_mismatch_is_rejected() {
    let Some((_rt, bundle)) = load("logreg") else { return };
    let bad = Batch { x: BatchData::I32(vec![0; 16 * 64]), y: vec![0; 16] };
    assert!(bundle.grad.run(&bundle.init_theta, &bad, 0).is_err());
}
