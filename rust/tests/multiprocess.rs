//! Multi-process cluster integration tests: real spawned worker
//! processes talking to the leader over localhost TCP.
//!
//! These are the acceptance tests of the socket transport:
//!
//! 1. with K = n, a `--transport tcp --spawn-workers` run is **bitwise
//!    identical** in loss and θ trajectories to `InProc`, across all six
//!    protocol strings;
//! 2. killing one worker mid-run under `--quorum K < n` keeps the loss
//!    descending, with the dead worker accounted in `dropped_uplinks`;
//! 3. a killed worker **rejoins**: whether relaunched by the
//!    supervisor's restart-backoff policy or launched externally by
//!    hand, the replacement HELLOs back into the dead wid, the quorum
//!    target recovers, and the lost error-feedback accumulator is
//!    zeroed and accounted (`ef_resets` / `ef_residual_lost_bits`).
//!
//! The spawned program is the real `comp-ams` launcher: integration
//! tests run inside the test harness binary, so the supervisor is
//! pointed at the launcher via `COMP_AMS_WORKER_BIN`
//! (cargo builds and exposes it as `CARGO_BIN_EXE_comp-ams`).

use std::time::Duration;

use comp_ams::algo::AlgoSpec;
use comp_ams::config::TrainConfig;
use comp_ams::coordinator::runtime::ClusterRuntime;
use comp_ams::coordinator::supervisor::{RestartPolicy, Supervisor, WORKER_BIN_ENV};
use comp_ams::coordinator::trainer::Trainer;
use comp_ams::coordinator::{CommLedger, TcpLeader};

/// Point the supervisor at the real launcher binary (the default,
/// `current_exe`, is this test harness).
fn use_real_worker_bin() {
    std::env::set_var(WORKER_BIN_ENV, env!("CARGO_BIN_EXE_comp-ams"));
}

fn quad_cfg(algo: &str) -> TrainConfig {
    let mut cfg = TrainConfig::preset("quadratic", algo);
    cfg.workers = 3;
    cfg.rounds = 20;
    cfg.lr = 0.02;
    cfg.eval_every = 0;
    cfg
}

/// Step every round through a `Trainer`, tear the cluster down cleanly,
/// and return (losses, θ, per-worker uplink bits, framing bits).
fn run_to_end(cfg: &TrainConfig) -> (Vec<f32>, Vec<f32>, Vec<u64>, u64) {
    let mut t = Trainer::new(cfg).unwrap();
    let mut losses = Vec::new();
    for r in 0..cfg.rounds {
        losses.push(t.step(r).unwrap());
    }
    t.finish().unwrap();
    let bits = t.ledger().uplink_bits_by_worker.clone();
    let framing = t.ledger().framing_bits;
    (losses, t.theta, bits, framing)
}

#[test]
fn spawned_tcp_cluster_is_bitwise_identical_to_inproc() {
    use_real_worker_bin();
    for algo in [
        "dist-ams",
        "comp-ams-topk:0.05",
        "comp-ams-blocksign:64",
        "qadam",
        "1bitadam:10",
        "dist-sgd",
    ] {
        let cfg = quad_cfg(algo);
        let (base_loss, base_theta, base_bits, base_framing) = run_to_end(&cfg);
        assert_eq!(base_framing, 0, "{algo}: inproc bills no framing");

        let mut cfg = quad_cfg(algo);
        cfg.transport = "tcp".into();
        cfg.spawn_workers = true;
        let (loss, theta, bits, framing) = run_to_end(&cfg);

        assert_eq!(base_bits, bits, "{algo}: per-worker uplink bits");
        // Framing is billed per message (uplinks + downlinks), never in
        // the uplink ledger: 25 bytes per frame, 2n messages per round.
        assert_eq!(
            framing,
            cfg.rounds * cfg.workers as u64 * 2 * 25 * 8,
            "{algo}: framing bill"
        );
        for (r, (a, b)) in base_loss.iter().zip(&loss).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "{algo}: loss diverged at round {r}");
        }
        for (i, (a, b)) in base_theta.iter().zip(&theta).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "{algo}: θ[{i}] diverged");
        }
    }
}

#[test]
fn killed_worker_becomes_permanent_straggler_under_partial_quorum() {
    use_real_worker_bin();
    let mut cfg = quad_cfg("comp-ams-topk:0.05");
    cfg.workers = 4;
    cfg.quorum = 3;
    cfg.max_staleness = 2;
    cfg.rounds = 40;
    cfg.lr = 0.05;
    cfg.transport = "tcp".into();

    // Assemble the cluster by hand so one worker can be fault-injected:
    // `--exit-after 5` makes it crash on receiving the round-5 downlink,
    // *before* uplinking — it dies owing the leader an uplink.
    let leader = TcpLeader::bind(0).unwrap();
    let addr = leader.local_addr().unwrap().to_string();
    let mut sup = Supervisor::spawn_with(cfg.workers, &addr, |i| {
        if i == 0 {
            vec!["--exit-after".into(), "5".into()]
        } else {
            Vec::new()
        }
    })
    .unwrap();
    let tcp = leader.accept_workers(&cfg).unwrap();
    let mut rt = ClusterRuntime::new(Box::new(tcp), cfg.quorum, cfg.max_staleness).unwrap();
    let spec = AlgoSpec::parse(&cfg.algo).unwrap();
    let (_, mut server) = spec.build(256, cfg.workers, cfg.rounds);
    let mut theta = vec![0.0f32; 256];
    let mut ledger = CommLedger::new();

    let mut losses = Vec::new();
    for r in 0..cfg.rounds {
        let out = rt
            .run_round(&mut theta, server.as_mut(), r, cfg.lr, &mut ledger)
            .unwrap_or_else(|e| panic!("round {r}: {e:#}"));
        losses.push(out.train_loss);
    }
    rt.drain_in_flight(&mut ledger).unwrap();
    rt.shutdown().unwrap();

    // The crash was absorbed: exactly one permanent straggler, its owed
    // uplink accounted as dropped, and the surviving quorum kept
    // descending.
    assert_eq!(rt.dead_workers().len(), 1, "one worker should be dead");
    assert!(
        ledger.dropped_uplinks >= 1,
        "dead worker's owed uplink must land in dropped_uplinks"
    );
    let first = losses[0];
    let last = losses[losses.len() - 10..].iter().sum::<f32>() / 10.0;
    assert!(last < first - 0.3, "no descent after the crash: {first:.3} -> {last:.3}");

    // Reap: the injected crash exits non-zero, everyone else exits zero
    // on SHUTDOWN; nobody is left running.
    let reports = sup.reap(Duration::from_secs(10)).unwrap();
    let nonzero = reports.iter().filter(|r| !r.status.success()).count();
    assert_eq!(nonzero, 1, "exactly the fault-injected worker exits non-zero");
    assert_eq!(sup.alive().unwrap(), 0);
}

#[test]
fn killed_worker_rejoins_after_supervised_restart() {
    use_real_worker_bin();
    let mut cfg = quad_cfg("comp-ams-topk:0.05");
    cfg.workers = 4;
    cfg.quorum = 3;
    cfg.max_staleness = 2;
    cfg.rounds = 60;
    cfg.lr = 0.05;
    cfg.transport = "tcp".into();

    // Worker 0 crashes on the round-5 downlink (exit 17, owing an
    // uplink). The armed restart policy relaunches its slot — with the
    // fault injection stripped via `set_restart_argv`, so the
    // replacement does not crash all over again — and the fresh daemon
    // HELLOs back into wid 0 through the leader's retained listener.
    let leader = TcpLeader::bind(0).unwrap();
    let addr = leader.local_addr().unwrap().to_string();
    let mut sup = Supervisor::spawn_with(cfg.workers, &addr, |i| {
        if i == 0 {
            vec!["--exit-after".into(), "5".into()]
        } else {
            Vec::new()
        }
    })
    .unwrap();
    sup.set_restart_policy(RestartPolicy {
        max_restarts: 3,
        base_delay: Duration::from_millis(50),
        max_delay: Duration::from_secs(1),
    });
    sup.set_restart_argv(0, Vec::new()).unwrap();

    let tcp = leader.accept_workers(&cfg).unwrap();
    let mut rt = ClusterRuntime::new(Box::new(tcp), cfg.quorum, cfg.max_staleness).unwrap();
    let spec = AlgoSpec::parse(&cfg.algo).unwrap();
    rt.set_ef_state_bits(spec.ef_state_bits(256));
    let (_, mut server) = spec.build(256, cfg.workers, cfg.rounds);
    let mut theta = vec![0.0f32; 256];
    let mut ledger = CommLedger::new();

    let mut losses = Vec::new();
    let mut seen_dead = false;
    let mut dropped_after_rejoin = None;
    for r in 0..cfg.rounds {
        sup.tick().unwrap();
        let out = rt
            .run_round(&mut theta, server.as_mut(), r, cfg.lr, &mut ledger)
            .unwrap_or_else(|e| panic!("round {r}: {e:#}"));
        losses.push(out.train_loss);
        if !rt.dead_workers().is_empty() {
            seen_dead = true;
            // Rounds are sub-millisecond; give the backoff timer and the
            // replacement's HELLO a moment to land before re-dispatching.
            std::thread::sleep(Duration::from_millis(25));
        } else if seen_dead && dropped_after_rejoin.is_none() {
            dropped_after_rejoin = Some(ledger.dropped_uplinks);
        }
    }
    rt.drain_in_flight(&mut ledger).unwrap();
    rt.shutdown().unwrap();

    // The fleet healed: the crash was observed, the replacement was
    // admitted back into wid 0, and the quorum target recovered.
    assert!(seen_dead, "the fault injection never fired");
    assert_eq!(rt.dead_workers(), Vec::<usize>::new(), "worker 0 never rejoined");
    assert!(ledger.rejoins >= 1, "rejoin not recorded in the ledger");
    // The dead incarnation's EF accumulator is gone: zeroed and
    // accounted exactly once (32 bits x 256 dims), not silently hidden.
    assert_eq!(ledger.ef_resets, 1);
    assert_eq!(ledger.ef_residual_lost_bits, spec.ef_state_bits(256));
    // The owed uplink was dropped at death, and after the rejoin the
    // drop counter stops growing — dead-worker decay is over.
    let after = dropped_after_rejoin.expect("no post-rejoin round observed");
    assert!(after >= 1, "dead worker's owed uplink must be dropped");
    assert_eq!(
        ledger.dropped_uplinks, after,
        "dropped_uplinks kept growing after the rejoin"
    );
    let first = losses[0];
    let last = losses[losses.len() - 10..].iter().sum::<f32>() / 10.0;
    assert!(last < first - 0.3, "no descent across the crash: {first:.3} -> {last:.3}");

    // The supervisor saw exactly the injected status-17 crash; the
    // replacement (and everyone else) exits zero on SHUTDOWN.
    assert_eq!(sup.nonzero_exits(), &[(0, Some(17))]);
    let reports = sup.reap(Duration::from_secs(10)).unwrap();
    assert!(
        reports.iter().all(|r| r.status.success()),
        "a final fleet member exited non-zero: {reports:?}"
    );
    assert_eq!(sup.alive().unwrap(), 0);
}

#[test]
fn externally_launched_replacement_rejoins_mid_run() {
    // The two-terminal workflow under failure: no supervisor at all —
    // when the fault-injected daemon dies, "the operator" launches a
    // fresh `comp-ams worker` by hand and it rejoins the dead wid.
    use_real_worker_bin();
    let mut cfg = quad_cfg("comp-ams-topk:0.05");
    cfg.workers = 3;
    cfg.quorum = 2;
    cfg.max_staleness = 2;
    cfg.rounds = 50;
    cfg.lr = 0.05;
    cfg.transport = "tcp".into();

    let leader = TcpLeader::bind(0).unwrap();
    let addr = leader.local_addr().unwrap().to_string();
    let spawn_worker = |extra: &[&str]| {
        let mut args = vec!["worker", "--leader", addr.as_str()];
        args.extend_from_slice(extra);
        std::process::Command::new(env!("CARGO_BIN_EXE_comp-ams"))
            .args(&args)
            .stdin(std::process::Stdio::null())
            .stdout(std::process::Stdio::null())
            .spawn()
            .unwrap()
    };
    let mut children = vec![spawn_worker(&["--exit-after", "4"])];
    for _ in 1..cfg.workers {
        children.push(spawn_worker(&[]));
    }

    let tcp = leader.accept_workers(&cfg).unwrap();
    let mut rt = ClusterRuntime::new(Box::new(tcp), cfg.quorum, cfg.max_staleness).unwrap();
    let spec = AlgoSpec::parse(&cfg.algo).unwrap();
    rt.set_ef_state_bits(spec.ef_state_bits(256));
    let (_, mut server) = spec.build(256, cfg.workers, cfg.rounds);
    let mut theta = vec![0.0f32; 256];
    let mut ledger = CommLedger::new();

    let mut losses = Vec::new();
    let mut replacement: Option<std::process::Child> = None;
    for r in 0..cfg.rounds {
        let out = rt
            .run_round(&mut theta, server.as_mut(), r, cfg.lr, &mut ledger)
            .unwrap_or_else(|e| panic!("round {r}: {e:#}"));
        losses.push(out.train_loss);
        if !rt.dead_workers().is_empty() {
            if replacement.is_none() {
                replacement = Some(spawn_worker(&[]));
            }
            std::thread::sleep(Duration::from_millis(20));
        }
    }
    rt.drain_in_flight(&mut ledger).unwrap();
    rt.shutdown().unwrap();

    let mut replacement = replacement.expect("the fault injection never fired");
    assert_eq!(rt.dead_workers(), Vec::<usize>::new(), "replacement never rejoined");
    assert!(ledger.rejoins >= 1);
    assert_eq!(ledger.ef_resets, 1);
    let first = losses[0];
    let last = losses[losses.len() - 10..].iter().sum::<f32>() / 10.0;
    assert!(last < first - 0.3, "no descent across the crash: {first:.3} -> {last:.3}");

    // Exit statuses: the injected crash is 17; the survivors and the
    // replacement exit zero on SHUTDOWN.
    let mut statuses = Vec::new();
    for c in children.iter_mut() {
        statuses.push(c.wait().unwrap());
    }
    assert_eq!(statuses[0].code(), Some(17), "fault-injected daemon status");
    assert!(statuses[1..].iter().all(|s| s.success()));
    assert!(replacement.wait().unwrap().success(), "replacement should exit 0");
}

#[test]
fn externally_launched_workers_form_the_same_cluster() {
    // No supervisor: launch the daemons ourselves (the two-terminal
    // workflow from the README) and check the run still descends.
    use_real_worker_bin();
    let mut cfg = quad_cfg("comp-ams-blocksign:64");
    cfg.workers = 2;
    cfg.rounds = 30;
    cfg.lr = 0.05;
    cfg.transport = "tcp".into();

    let leader = TcpLeader::bind(0).unwrap();
    let addr = leader.local_addr().unwrap().to_string();
    let mut children: Vec<std::process::Child> = (0..cfg.workers)
        .map(|_| {
            std::process::Command::new(env!("CARGO_BIN_EXE_comp-ams"))
                .args(["worker", "--leader", &addr])
                .stdin(std::process::Stdio::null())
                .stdout(std::process::Stdio::null())
                .spawn()
                .unwrap()
        })
        .collect();
    let tcp = leader.accept_workers(&cfg).unwrap();
    let mut rt = ClusterRuntime::new(Box::new(tcp), 0, cfg.max_staleness).unwrap();
    let spec = AlgoSpec::parse(&cfg.algo).unwrap();
    let (_, mut server) = spec.build(256, cfg.workers, cfg.rounds);
    let mut theta = vec![0.0f32; 256];
    let mut ledger = CommLedger::new();
    let mut losses = Vec::new();
    for r in 0..cfg.rounds {
        losses.push(
            rt.run_round(&mut theta, server.as_mut(), r, cfg.lr, &mut ledger)
                .unwrap()
                .train_loss,
        );
    }
    rt.drain_in_flight(&mut ledger).unwrap();
    rt.shutdown().unwrap();
    assert!(losses[losses.len() - 1] < losses[0] - 0.3);
    assert_eq!(ledger.stale_uplinks, 0);
    assert_eq!(ledger.dropped_uplinks, 0);
    // The daemons exit 0 on SHUTDOWN.
    for c in children.iter_mut() {
        let status = c.wait().unwrap();
        assert!(status.success(), "worker exited {status:?}");
    }
}
