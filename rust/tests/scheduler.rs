//! Scheduler integration tests: a real `comp-ams serve` daemon driving
//! real worker processes over localhost TCP, exercised through the
//! line-JSON control protocol.
//!
//! These are the acceptance tests of the resident-leader subsystem:
//!
//! 1. one fleet serves **many queued jobs** with different configs, and
//!    each job's trajectory, per-worker uplink-bit ledger, and final θ
//!    are **bitwise identical** to the same config run solo — per-job
//!    accounting never bleeds across jobs sharing the fleet;
//! 2. a higher-priority submission **preempts** the running job, which
//!    is checkpointed, later resumed, and still finishes bitwise
//!    identical to an uninterrupted run;
//! 3. `cancel` stops a running job at a round boundary; `drain` lets the
//!    daemon finish queued work and exit 0; SIGINT checkpoints the
//!    active job and also exits 0 (fleet released, children reaped);
//! 4. the fleet **heals**: a worker daemon that dies is probed out and
//!    evicted at the next assign, a job that no longer fits fails fast
//!    with an error naming the evicted slot, and an externally launched
//!    replacement is re-admitted so later jobs run (bitwise clean).
//!
//! The daemon's ephemeral fleet/control ports are discovered from its
//! `fleet-addr` / `control-addr` stdout announcements — the same
//! mechanism CI's smoke job uses.

use std::io::{BufRead, BufReader};
use std::process::{Child, Command, Stdio};
use std::time::{Duration, Instant};

use comp_ams::config::TrainConfig;
use comp_ams::coordinator::metrics::RunResult;
use comp_ams::coordinator::scheduler::{request, theta_to_hex};
use comp_ams::coordinator::trainer::Trainer;
use comp_ams::util::json::Json;

/// Launch `comp-ams serve` with an ephemeral control port; returns the
/// child and its announced (fleet, control) addresses. With
/// `spawn_workers` false the caller must launch the worker daemons
/// itself against the returned fleet address.
fn start_daemon_with(workers: usize, spawn_workers: bool) -> (Child, String, String) {
    let mut child = Command::new(env!("CARGO_BIN_EXE_comp-ams"))
        .args([
            "serve",
            "--workers",
            &workers.to_string(),
            "--spawn-workers",
            if spawn_workers { "true" } else { "false" },
            "--transport",
            "tcp",
            "--control",
            "0",
        ])
        .stdin(Stdio::null())
        .stdout(Stdio::piped())
        .stderr(Stdio::null())
        .spawn()
        .unwrap();
    let mut reader = BufReader::new(child.stdout.take().unwrap());
    let (mut fleet, mut control) = (None, None);
    while fleet.is_none() || control.is_none() {
        let mut line = String::new();
        assert!(
            reader.read_line(&mut line).unwrap() > 0,
            "serve exited before announcing its addresses"
        );
        if let Some(rest) = line.trim().strip_prefix("fleet-addr ") {
            fleet = Some(rest.to_string());
        } else if let Some(rest) = line.trim().strip_prefix("control-addr ") {
            control = Some(rest.to_string());
        }
    }
    (child, fleet.unwrap(), control.unwrap())
}

/// Launch `comp-ams serve` with a spawned fleet; returns the child and
/// its announced control address.
fn start_daemon(workers: usize) -> (Child, String) {
    let (child, _fleet, control) = start_daemon_with(workers, true);
    (child, control)
}

fn submit(addr: &str, name: &str, priority: i64, cfg: &TrainConfig) -> u64 {
    let resp = request(
        addr,
        &Json::obj(vec![
            ("cmd", Json::str("submit")),
            ("name", Json::str(name)),
            ("priority", Json::num(priority as f64)),
            ("config", cfg.to_json()),
        ]),
    )
    .unwrap();
    resp.req("id").unwrap().as_usize().unwrap() as u64
}

/// Fetch one job's row from a `status` response.
fn job_row(addr: &str, id: u64) -> Json {
    let resp =
        request(addr, &Json::obj(vec![("cmd", Json::str("status"))])).unwrap();
    resp.req("jobs")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .find(|j| j.req("id").unwrap().as_usize().unwrap() as u64 == id)
        .unwrap_or_else(|| panic!("job {id} missing from status"))
        .clone()
}

/// Poll `status` until the job reaches `want` (or fail after 120 s — the
/// fleet is real processes, CI machines are slow).
fn wait_for_state(addr: &str, id: u64, want: &str) -> Json {
    let start = Instant::now();
    loop {
        let job = job_row(addr, id);
        let state = job.req("state").unwrap().as_str().unwrap().to_string();
        if state == want {
            return job;
        }
        assert!(
            !matches!(state.as_str(), "failed" | "cancelled" | "done"),
            "job {id} ended as {state} (wanted {want}): {}",
            job.to_string_compact()
        );
        assert!(
            start.elapsed() < Duration::from_secs(120),
            "job {id} stuck in {state} (wanted {want})"
        );
        std::thread::sleep(Duration::from_millis(5));
    }
}

/// Run the same config solo (in-process transport) and return its final
/// θ and `RunResult` — the bitwise reference for a scheduled job.
fn solo(cfg: &TrainConfig) -> (Vec<f32>, RunResult) {
    let mut cfg = cfg.clone();
    cfg.transport = "inproc".into();
    cfg.spawn_workers = false;
    let mut t = Trainer::new(&cfg).unwrap();
    for r in 0..cfg.rounds {
        t.step(r).unwrap();
    }
    let theta = t.theta.clone();
    (theta, t.finalize().unwrap())
}

fn quad_cfg(algo: &str, workers: usize, rounds: u64, seed: u64) -> TrainConfig {
    let mut cfg = TrainConfig::preset("quadratic", algo);
    cfg.workers = workers;
    cfg.rounds = rounds;
    cfg.lr = 0.02;
    cfg.seed = seed;
    cfg.eval_every = 0;
    cfg
}

/// Assert a done job's control-protocol row matches its solo reference
/// bitwise: θ, per-worker uplink bits, final losses — plus the framing
/// bill the fleet transport must have charged for exactly this job's
/// messages (25-byte headers, 2 per worker per round).
fn assert_matches_solo(job: &Json, cfg: &TrainConfig, theta: &[f32], run: &RunResult) {
    assert_eq!(
        job.req("theta_hex").unwrap().as_str().unwrap(),
        theta_to_hex(theta),
        "final θ diverged from the solo run"
    );
    let result = job.req("result").unwrap();
    let bits: Vec<u64> = result
        .req("uplink_bits_by_worker")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|b| b.as_f64().unwrap() as u64)
        .collect();
    assert_eq!(bits, run.uplink_bits_by_worker, "per-worker uplink ledger");
    assert_eq!(
        result.req("uplink_bits").unwrap().as_f64().unwrap() as u64,
        run.uplink_bits()
    );
    assert_eq!(
        result.req("rounds").unwrap().as_usize().unwrap() as u64,
        cfg.rounds
    );
    assert_eq!(
        result.req("final_train_loss").unwrap().as_f64().unwrap(),
        f64::from(run.final_train_loss(10)),
        "final train loss diverged"
    );
    assert_eq!(
        result.req("final_eval_loss").unwrap().as_f64().unwrap(),
        f64::from(run.final_eval.loss)
    );
    // The fleet bills framing for this job's own messages only.
    assert_eq!(
        result.req("framing_bits").unwrap().as_f64().unwrap() as u64,
        cfg.rounds * cfg.workers as u64 * 2 * 25 * 8,
        "framing bill"
    );
    assert_eq!(result.req("stale_uplinks").unwrap().as_f64().unwrap(), 0.0);
    assert_eq!(result.req("dropped_uplinks").unwrap().as_f64().unwrap(), 0.0);
}

#[test]
fn one_fleet_serves_many_jobs_with_disjoint_bitwise_ledgers() {
    let (mut child, addr) = start_daemon(3);

    // Two jobs with different algos, worker counts, rounds, and seeds —
    // queued together, run back-to-back over the same fleet.
    let cfg_a = quad_cfg("dist-sgd", 3, 25, 42);
    let cfg_b = quad_cfg("comp-ams-topk:0.1", 2, 40, 7);
    let (theta_a, run_a) = solo(&cfg_a);
    let (theta_b, run_b) = solo(&cfg_b);

    let id_a = submit(&addr, "job-a", 0, &cfg_a);
    let id_b = submit(&addr, "job-b", 0, &cfg_b);
    let job_a = wait_for_state(&addr, id_a, "done");
    let job_b = wait_for_state(&addr, id_b, "done");

    assert_matches_solo(&job_a, &cfg_a, &theta_a, &run_a);
    assert_matches_solo(&job_b, &cfg_b, &theta_b, &run_b);
    // Ledger disjointness, stated directly: each job's bill is exactly
    // its own solo bill, and the two differ (different configs), so no
    // bits leaked from one job's accounting into the other's.
    assert_ne!(run_a.uplink_bits(), run_b.uplink_bits());
    assert_eq!(job_a.req("name").unwrap().as_str().unwrap(), "job-a");

    // Cancel path: a long job gets cancelled at a round boundary.
    let id_c = submit(&addr, "doomed", 0, &quad_cfg("dist-sgd", 2, 1_000_000, 1));
    request(
        &addr,
        &Json::obj(vec![("cmd", Json::str("cancel")), ("id", Json::num(id_c as f64))]),
    )
    .unwrap();
    let start = Instant::now();
    loop {
        let state = job_row(&addr, id_c);
        if state.req("state").unwrap().as_str().unwrap() == "cancelled" {
            break;
        }
        assert!(start.elapsed() < Duration::from_secs(120), "cancel never landed");
        std::thread::sleep(Duration::from_millis(5));
    }

    // Drain: the daemon finishes (nothing runnable remains) and exits 0,
    // releasing the fleet and reaping its spawned workers.
    request(&addr, &Json::obj(vec![("cmd", Json::str("drain"))])).unwrap();
    let status = child.wait().unwrap();
    assert!(status.success(), "serve exited {status:?}");
}

#[test]
fn preempted_job_resumes_bitwise_identical_to_uninterrupted() {
    let (mut child, addr) = start_daemon(2);

    // A long low-priority job (EF-carrying compressor, so suspended
    // state actually matters) and a short high-priority one.
    let cfg_low = quad_cfg("comp-ams-topk:0.1", 2, 1000, 3);
    let cfg_high = quad_cfg("qadam", 2, 10, 9);
    let (theta_low, run_low) = solo(&cfg_low);
    let (theta_high, run_high) = solo(&cfg_high);

    let id_low = submit(&addr, "background", 0, &cfg_low);
    // Wait until it is actually running (and has made some progress) so
    // the high-priority submission lands mid-job.
    let start = Instant::now();
    loop {
        let job = job_row(&addr, id_low);
        if job.req("state").unwrap().as_str().unwrap() == "running"
            && job.req("rounds_done").unwrap().as_usize().unwrap() >= 1
        {
            break;
        }
        assert!(start.elapsed() < Duration::from_secs(120), "job never started");
        std::thread::sleep(Duration::from_millis(2));
    }
    let id_high = submit(&addr, "urgent", 5, &cfg_high);

    let job_high = wait_for_state(&addr, id_high, "done");
    let job_low = wait_for_state(&addr, id_low, "done");

    // The background job was preempted at least once, checkpointed, and
    // resumed — and its whole trajectory is still bitwise identical to
    // an uninterrupted solo run, ledger included.
    assert!(
        job_low.req("preemptions").unwrap().as_usize().unwrap() >= 1,
        "the high-priority job should have preempted the background job: {}",
        job_low.to_string_compact()
    );
    assert_matches_solo(&job_low, &cfg_low, &theta_low, &run_low);
    assert_matches_solo(&job_high, &cfg_high, &theta_high, &run_high);

    request(&addr, &Json::obj(vec![("cmd", Json::str("drain"))])).unwrap();
    assert!(child.wait().unwrap().success());
}

#[test]
fn fleet_heals_after_a_worker_death_and_names_the_dead_slot_meanwhile() {
    // External fleet so the worker argv is ours: worker 0 carries the
    // `--exit-after` fault injection and dies during job A.
    let (mut child, fleet_addr, addr) = start_daemon_with(2, false);
    let spawn_worker = |extra: &[&str]| {
        let mut args = vec!["worker", "--leader", fleet_addr.as_str()];
        args.extend_from_slice(extra);
        Command::new(env!("CARGO_BIN_EXE_comp-ams"))
            .args(&args)
            .stdin(Stdio::null())
            .stdout(Stdio::null())
            .stderr(Stdio::null())
            .spawn()
            .unwrap()
    };
    let mut doomed = spawn_worker(&["--exit-after", "5"]);
    let mut survivor = spawn_worker(&[]);

    // Job A absorbs the mid-job crash: the per-job runtime marks the
    // wid dead, keeps stepping on the survivor, and bills the decay —
    // dropped uplinks plus the EF accumulator that died with the
    // process. No mid-job rejoin on a pooled transport (the daemon
    // heals at job boundaries), so rejoins stays 0 here.
    let cfg_a = quad_cfg("comp-ams-topk:0.1", 2, 20, 42);
    let id_a = submit(&addr, "job-a", 0, &cfg_a);
    let job_a = wait_for_state(&addr, id_a, "done");
    let result = job_a.req("result").unwrap();
    assert!(result.req("dropped_uplinks").unwrap().as_f64().unwrap() >= 1.0);
    assert_eq!(result.req("ef_resets").unwrap().as_f64().unwrap(), 1.0);
    assert_eq!(
        result.req("ef_residual_lost_bits").unwrap().as_f64().unwrap(),
        f64::from(32u32 * 256),
        "one EF reset = 32 bits x 256 dims"
    );
    assert_eq!(result.req("rejoins").unwrap().as_f64().unwrap(), 0.0);
    assert_eq!(doomed.wait().unwrap().code(), Some(17), "fault injection status");

    // Job B wants the full fleet while it is short one worker: the
    // assign-time liveness probe evicts the dead socket and the job
    // fails fast, naming the evicted slot — it is never silently
    // assigned onto a dead socket.
    let id_b = submit(&addr, "job-b", 0, &quad_cfg("dist-sgd", 2, 10, 7));
    let start = Instant::now();
    let job_b = loop {
        let job = job_row(&addr, id_b);
        if job.req("state").unwrap().as_str().unwrap() == "failed" {
            break job;
        }
        assert!(start.elapsed() < Duration::from_secs(120), "job B never failed");
        std::thread::sleep(Duration::from_millis(5));
    };
    let err = job_b.req("error").unwrap().as_str().unwrap().to_string();
    assert!(
        err.contains("wants 2 workers but the fleet has 1 live"),
        "fail-fast error should count the live fleet: {err}"
    );
    assert!(err.contains("slot "), "fail-fast error should name the dead slot: {err}");

    // Heal: launch a replacement by hand; the daemon re-admits its
    // HELLO (idle tick or next assign) and job C runs on the restored
    // fleet — bitwise identical to solo, nothing dropped.
    let mut replacement = spawn_worker(&[]);
    std::thread::sleep(Duration::from_millis(500));
    let cfg_c = quad_cfg("comp-ams-topk:0.1", 2, 15, 9);
    let (theta_c, run_c) = solo(&cfg_c);
    let id_c = submit(&addr, "job-c", 0, &cfg_c);
    let job_c = wait_for_state(&addr, id_c, "done");
    assert_matches_solo(&job_c, &cfg_c, &theta_c, &run_c);

    request(&addr, &Json::obj(vec![("cmd", Json::str("drain"))])).unwrap();
    assert!(child.wait().unwrap().success());
    // The survivors exit 0 on the fleet SHUTDOWN.
    assert!(survivor.wait().unwrap().success());
    assert!(replacement.wait().unwrap().success());
}

#[test]
fn sigint_checkpoints_the_active_job_and_exits_cleanly() {
    let (mut child, addr) = start_daemon(2);
    let id = submit(&addr, "interrupted", 0, &quad_cfg("dist-sgd", 2, 1_000_000, 5));
    let start = Instant::now();
    loop {
        let job = job_row(&addr, id);
        if job.req("rounds_done").unwrap().as_usize().unwrap() >= 1 {
            break;
        }
        assert!(start.elapsed() < Duration::from_secs(120), "job never started");
        std::thread::sleep(Duration::from_millis(2));
    }

    extern "C" {
        fn kill(pid: i32, sig: i32) -> i32;
    }
    assert_eq!(unsafe { kill(child.id() as i32, 2 /* SIGINT */) }, 0);

    // Graceful shutdown: the active job is suspended (drained uplinks,
    // checkpointed state), the fleet is released, children are reaped,
    // and the daemon exits 0 — not killed by the signal.
    let status = child.wait().unwrap();
    assert!(status.success(), "serve exited {status:?} on SIGINT");
}
